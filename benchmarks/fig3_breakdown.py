"""Paper Fig. 3: Lanczos bidiagonalization runtime breakdown.

Times each op class of the inner loop separately (matvec, rmatvec, U-reorth,
V-reorth, normalize, small-SVD) on a [4096, 4096] activation at rank 10 and
reports the fraction of total — the paper's claim: the two
re-orthogonalizations dominate.
"""
from __future__ import annotations

from typing import List

import jax
import jax.numpy as jnp

from .common import Row, wall


def run(quick: bool = False) -> List[Row]:
    s = h = 1024 if quick else 4096
    k = 10
    key = jax.random.PRNGKey(0)
    a = jax.random.normal(key, (s, h), jnp.float32)
    u = jax.random.normal(jax.random.PRNGKey(1), (s,), jnp.float32)
    v = jax.random.normal(jax.random.PRNGKey(2), (h,), jnp.float32)
    qu = jnp.linalg.qr(jax.random.normal(jax.random.PRNGKey(3), (s, k)))[0]
    qv = jnp.linalg.qr(jax.random.normal(jax.random.PRNGKey(4), (h, k)))[0]
    b = jnp.diag(jnp.abs(jax.random.normal(jax.random.PRNGKey(5), (k,))))

    ops = {
        "matvec_Av": jax.jit(lambda: a @ v),
        "rmatvec_ATu": jax.jit(lambda: a.T @ u),
        "reorth_V": jax.jit(lambda: (lambda z: z - qv @ (qv.T @ z))(
            (lambda z: z - qv @ (qv.T @ z))(a.T @ u))),
        "reorth_U": jax.jit(lambda: (lambda z: z - qu @ (qu.T @ z))(
            (lambda z: z - qu @ (qu.T @ z))(a @ v))),
        "normalize": jax.jit(lambda: v / jnp.linalg.norm(v)),
        "small_svd_B": jax.jit(lambda: jnp.linalg.svd(b)),
    }
    times = {name: wall(fn) for name, fn in ops.items()}
    # per Lanczos iteration: 1 reorth_V + 1 reorth_U (each embeds its matvec)
    per_iter = times["reorth_V"] + times["reorth_U"] + 2 * times["normalize"]
    total = per_iter * k + times["small_svd_B"]
    rows: List[Row] = []
    for name, t in times.items():
        mult = k if "reorth" in name or "matvec" in name else \
            (2 * k if name == "normalize" else 1)
        frac = t * mult / total
        rows.append((f"fig3/{name}", t * 1e6, f"frac_of_total={frac:.2%}"))
    reorth_frac = (times["reorth_V"] + times["reorth_U"]) * k / total
    rows.append(("fig3/reorth_dominates", 0.0,
                 f"reorth_frac={reorth_frac:.2%}"))
    return rows


if __name__ == "__main__":
    from .common import emit
    emit(run())
