"""Serving admission A/B: per-slot splice admission vs legacy gang, and
async vs synchronous prefill under staggered long-prompt arrivals.

The tentpole claim of the per-slot serving engine: under STAGGERED
arrivals, gang admission of the decomposed-KV cache (block until every
slot is free, re-prefill the whole slot batch) wastes decode rounds and
queue time that per-slot splice admission does not.  Both engines replay
the SAME arrival schedule (requests keyed on engine step index) on the
same model/weights; reported are end-to-end tokens/sec, mean first-token
latency, and total scheduling steps.

The SECOND A/B targets the prefill/decode disaggregation (DESIGN.md
§12): short streams decode while LONG prompts (a full forward +
Lanczos decomposition each) arrive mid-flight.  The synchronous engine
serializes each admission into the decode loop, so every in-flight
stream's ITL spikes by the whole prefill; ``prefill_async=True``
dispatches the prefill and keeps decoding, splicing when the result
comes ready — p99 ITL is the number that moves.  The p99 assert is
enforced only when the backend can actually overlap independent
executables (``overlap_capable`` probe, recorded in the artifact): on a
single-core host CPU PJRT runs executables sequentially, so the decode
still queues behind the prefill no matter when it was dispatched —
there the artifact records both p99s without asserting, same policy as
``serving_sharded.py``'s host_cores gate.

CLI (writes the CI artifact):

  PYTHONPATH=src python -m benchmarks.serving_admission --quick \
      --json benchmarks/out/serving_admission.json
"""
from __future__ import annotations

import time
from typing import Dict, List

import numpy as np

from .common import Row, write_json


def _arrivals(cfg, requests: int, stagger: int, prompt_len: int,
              max_new: int) -> Dict[int, list]:
    from repro.serving import Request
    rng = np.random.RandomState(0)
    sched: Dict[int, list] = {}
    for i in range(requests):
        # heterogeneous decode lengths desynchronize completions — the
        # regime where gang admission (wait for EVERY slot to drain)
        # loses the most queue time
        req = Request(uid=i,
                      prompt=rng.randint(0, cfg.vocab, prompt_len,
                                         dtype=np.int32),
                      max_new_tokens=max_new + (i % 3) * max_new // 2)
        sched.setdefault(i * stagger, []).append(req)
    return sched


def _simulate(eng, arrivals: Dict[int, list], total: int,
              max_steps: int = 5000):
    t0 = time.perf_counter()
    done: List = []
    step = 0
    while len(done) < total and step < max_steps:
        for req in arrivals.get(step, []):
            eng.submit(req)
        done.extend(eng.step())
        step += 1
    wall = time.perf_counter() - t0
    assert len(done) == total, f"only {len(done)}/{total} finished"
    return wall, step


def _overlap_capable() -> bool:
    """Can this backend make progress on an independent small executable
    while a large one is in flight?  Times a tiny jitted op alone, then
    the same op dispatched BEHIND a large in-flight matmul chain: on a
    runtime with concurrent execution streams (or spare host cores) the
    two are comparable; on a serializing single-core CPU backend the
    small op waits for the whole matmul and comes back orders of
    magnitude slower.  Min-of-3 on both sides to shed scheduler noise."""
    import jax
    import jax.numpy as jnp
    big = jax.jit(lambda x: ((x @ x) @ x) @ x)
    small = jax.jit(lambda v: v * 2 + 1)
    x = jnp.ones((1024, 1024), jnp.float32)
    v = jnp.ones((256,), jnp.float32)
    big(x).block_until_ready()
    small(v).block_until_ready()
    alone = min(_timed(lambda: small(v).block_until_ready())
                for _ in range(3))
    behind = []
    for _ in range(3):
        h = big(x)
        behind.append(_timed(lambda: small(v).block_until_ready()))
        h.block_until_ready()
    return min(behind) < max(alone, 1e-6) * 10


def _timed(fn) -> float:
    t0 = time.perf_counter()
    fn()
    return time.perf_counter() - t0


def _async_arrivals(cfg, slots: int, n_long: int, stagger: int,
                    long_len: int, max_new: int) -> Dict[int, list]:
    """Short decode streams from step 0; LONG prompts (full prefill +
    Lanczos each) land mid-decode at ``stagger``-step intervals."""
    from repro.serving import Request
    rng = np.random.RandomState(1)
    sched: Dict[int, list] = {0: []}
    for i in range(slots):
        sched[0].append(Request(
            uid=i, prompt=rng.randint(0, cfg.vocab, 8, dtype=np.int32),
            max_new_tokens=max_new * 2))
    for k in range(n_long):
        sched.setdefault(3 + k * stagger, []).append(Request(
            uid=slots + k,
            prompt=rng.randint(0, cfg.vocab, long_len, dtype=np.int32),
            max_new_tokens=max_new))
    return sched


def run(quick: bool = False, json_path: str = None) -> List[Row]:
    import jax
    from repro.configs import all_archs
    from repro.models import model_fns
    from repro.obs import engine_snapshot
    from repro.serving import Engine

    cfg = all_archs()["deepseek-7b"].reduced()
    params = model_fns(cfg).init(jax.random.PRNGKey(0), cfg)
    requests = 6 if quick else 10
    slots = 2 if quick else 4
    max_len, prompt_len = 192, 12
    max_new = 16 if quick else 24
    stagger = 6                      # steps between arrivals

    rows: List[Row] = []
    report = {"arch": cfg.name, "slots": slots, "requests": requests,
              "stagger_steps": stagger, "kv_rank": 8, "modes": {}}
    for mode in ("per_slot", "gang"):
        mk = lambda: Engine(cfg, params, slots=slots, max_len=max_len,
                            decompose_kv_rank=8, dkv_tail=4, admission=mode)
        # warmup pass populates the shared jit caches; median of three
        # fresh-engine passes then times steady-state scheduling
        _simulate(mk(), _arrivals(cfg, requests, stagger, prompt_len,
                                  max_new), requests)
        runs = []
        for _ in range(3):
            eng = mk()
            wall, steps = _simulate(eng, _arrivals(cfg, requests, stagger,
                                                   prompt_len, max_new),
                                    requests)
            runs.append((wall, steps, eng))
        runs.sort(key=lambda t: t[0])
        wall, steps, eng = runs[len(runs) // 2]
        s = eng.stats
        tps = s.tokens_out / max(wall, 1e-9)
        # uniform repro.obs/v1 snapshot — same field names as every other
        # serving benchmark artifact and the serve CLI
        report["modes"][mode] = engine_snapshot(eng, wall_s=wall,
                                                sched_steps=steps)
        rows.append((f"serving_admission/{mode}/r{requests}xs{slots}",
                     wall * 1e6,
                     f"tok_per_s={tps:.1f};ttft_ms={s.mean_ttft_s*1e3:.1f};"
                     f"steps={steps}"))
    ps, gg = report["modes"]["per_slot"], report["modes"]["gang"]
    report["speedup_tokens_per_s"] = ps["tokens_per_s"] / \
        max(gg["tokens_per_s"], 1e-9)
    report["ttft_ratio_gang_over_per_slot"] = gg["ttft"]["mean_s"] / \
        max(ps["ttft"]["mean_s"], 1e-9)
    rows.append(("serving_admission/per_slot_vs_gang", 0.0,
                 f"tokens_per_s_speedup={report['speedup_tokens_per_s']:.2f}x;"
                 f"ttft_improvement="
                 f"{report['ttft_ratio_gang_over_per_slot']:.2f}x"))

    # -- async vs sync prefill: p99 ITL under staggered long admissions --
    n_long = 3 if quick else 5
    long_len = 64 if quick else 96
    stagger_l = 4
    total = slots + n_long
    overlap = _overlap_capable()
    ab: Dict[str, dict] = {}
    for label, akw in (("sync", {}),
                       ("async", dict(prefill_async=True,
                                      ready_order="ready"))):
        mk = lambda: Engine(cfg, params, slots=slots, max_len=max_len,
                            decompose_kv_rank=8, dkv_tail=16, **akw)
        _simulate(mk(), _async_arrivals(cfg, slots, n_long, stagger_l,
                                        long_len, max_new), total)
        runs = []
        for _ in range(3):
            eng = mk()
            wall, steps = _simulate(
                eng, _async_arrivals(cfg, slots, n_long, stagger_l,
                                     long_len, max_new), total)
            runs.append((wall, steps, eng))
        runs.sort(key=lambda t: t[0])
        wall, steps, eng = runs[len(runs) // 2]
        s = eng.stats
        ab[label] = engine_snapshot(eng, wall_s=wall, sched_steps=steps)
        rows.append((f"serving_admission/{label}_prefill/"
                     f"l{n_long}x{long_len}",
                     wall * 1e6,
                     f"p99_itl_ms={ab[label]['itl']['p99_s']*1e3:.2f};"
                     f"mean_itl_ms={ab[label]['itl']['mean_s']*1e3:.2f};"
                     f"inflight_peak={s.prefill_inflight_peak}"))
    ratio = ab["sync"]["itl"]["p99_s"] / max(ab["async"]["itl"]["p99_s"],
                                             1e-9)
    report["async_ab"] = {
        "n_long": n_long, "long_prompt_len": long_len,
        "stagger_steps": stagger_l, "overlap_capable": overlap,
        "modes": ab, "p99_itl_ratio_sync_over_async": ratio,
        "p99_gate": "enforced" if overlap else "skipped:no_overlap",
    }
    rows.append(("serving_admission/async_vs_sync_p99_itl", 0.0,
                 f"p99_itl_improvement={ratio:.2f}x;"
                 f"gate={'enforced' if overlap else 'skipped:no_overlap'}"))
    if json_path:
        write_json(json_path, report, indent=2)
    # the disaggregation claim, asserted only where the runtime can
    # actually overlap executables (artifact carries both p99s either way)
    if overlap:
        assert ratio > 1.0, \
            f"async prefill did not improve p99 ITL: {ratio:.2f}x"
    return rows


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--json", default=None, help="write the report here")
    args = ap.parse_args()
    for r in run(quick=args.quick, json_path=args.json):
        print(f"{r[0]},{r[1]:.3f},{r[2]}")
