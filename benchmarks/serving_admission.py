"""Serving admission A/B: per-slot splice admission vs legacy gang.

The tentpole claim of the per-slot serving engine: under STAGGERED
arrivals, gang admission of the decomposed-KV cache (block until every
slot is free, re-prefill the whole slot batch) wastes decode rounds and
queue time that per-slot splice admission does not.  Both engines replay
the SAME arrival schedule (requests keyed on engine step index) on the
same model/weights; reported are end-to-end tokens/sec, mean first-token
latency, and total scheduling steps.

CLI (writes the CI artifact):

  PYTHONPATH=src python -m benchmarks.serving_admission --quick \
      --json benchmarks/out/serving_admission.json
"""
from __future__ import annotations

import json
import time
from typing import Dict, List

import numpy as np

from .common import Row


def _arrivals(cfg, requests: int, stagger: int, prompt_len: int,
              max_new: int) -> Dict[int, list]:
    from repro.serving import Request
    rng = np.random.RandomState(0)
    sched: Dict[int, list] = {}
    for i in range(requests):
        # heterogeneous decode lengths desynchronize completions — the
        # regime where gang admission (wait for EVERY slot to drain)
        # loses the most queue time
        req = Request(uid=i,
                      prompt=rng.randint(0, cfg.vocab, prompt_len,
                                         dtype=np.int32),
                      max_new_tokens=max_new + (i % 3) * max_new // 2)
        sched.setdefault(i * stagger, []).append(req)
    return sched


def _simulate(eng, arrivals: Dict[int, list], total: int,
              max_steps: int = 5000):
    t0 = time.perf_counter()
    done: List = []
    step = 0
    while len(done) < total and step < max_steps:
        for req in arrivals.get(step, []):
            eng.submit(req)
        done.extend(eng.step())
        step += 1
    wall = time.perf_counter() - t0
    assert len(done) == total, f"only {len(done)}/{total} finished"
    return wall, step


def run(quick: bool = False, json_path: str = None) -> List[Row]:
    import jax
    from repro.configs import all_archs
    from repro.models import model_fns
    from repro.serving import Engine

    cfg = all_archs()["deepseek-7b"].reduced()
    params = model_fns(cfg).init(jax.random.PRNGKey(0), cfg)
    requests = 6 if quick else 10
    slots = 2 if quick else 4
    max_len, prompt_len = 192, 12
    max_new = 16 if quick else 24
    stagger = 6                      # steps between arrivals

    rows: List[Row] = []
    report = {"arch": cfg.name, "slots": slots, "requests": requests,
              "stagger_steps": stagger, "kv_rank": 8, "modes": {}}
    for mode in ("per_slot", "gang"):
        mk = lambda: Engine(cfg, params, slots=slots, max_len=max_len,
                            decompose_kv_rank=8, dkv_tail=4, admission=mode)
        # warmup pass populates the shared jit caches; median of three
        # fresh-engine passes then times steady-state scheduling
        _simulate(mk(), _arrivals(cfg, requests, stagger, prompt_len,
                                  max_new), requests)
        runs = []
        for _ in range(3):
            eng = mk()
            wall, steps = _simulate(eng, _arrivals(cfg, requests, stagger,
                                                   prompt_len, max_new),
                                    requests)
            runs.append((wall, steps, eng.stats))
        runs.sort(key=lambda t: t[0])
        wall, steps, s = runs[len(runs) // 2]
        tps = s.tokens_out / max(wall, 1e-9)
        report["modes"][mode] = {
            "wall_s": wall, "sched_steps": steps,
            "tokens_out": s.tokens_out, "tokens_per_s": tps,
            "prefills": s.prefills, "prefill_batches": s.prefill_batches,
            "tail_folds": s.tail_folds,
            "mean_ttft_s": s.mean_ttft_s, "mean_itl_s": s.mean_itl_s,
        }
        rows.append((f"serving_admission/{mode}/r{requests}xs{slots}",
                     wall * 1e6,
                     f"tok_per_s={tps:.1f};ttft_ms={s.mean_ttft_s*1e3:.1f};"
                     f"steps={steps}"))
    ps, gg = report["modes"]["per_slot"], report["modes"]["gang"]
    report["speedup_tokens_per_s"] = ps["tokens_per_s"] / \
        max(gg["tokens_per_s"], 1e-9)
    report["ttft_ratio_gang_over_per_slot"] = gg["mean_ttft_s"] / \
        max(ps["mean_ttft_s"], 1e-9)
    rows.append(("serving_admission/per_slot_vs_gang", 0.0,
                 f"tokens_per_s_speedup={report['speedup_tokens_per_s']:.2f}x;"
                 f"ttft_improvement="
                 f"{report['ttft_ratio_gang_over_per_slot']:.2f}x"))
    if json_path:
        import os
        os.makedirs(os.path.dirname(json_path) or ".", exist_ok=True)
        with open(json_path, "w") as f:
            json.dump(report, f, indent=2)
    return rows


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--json", default=None, help="write the report here")
    args = ap.parse_args()
    for r in run(quick=args.quick, json_path=args.json):
        print(f"{r[0]},{r[1]:.3f},{r[2]}")
