"""Beyond-paper table: decomposed-KV serving quality vs rank.

Companion to EXPERIMENTS.md §Perf cell C: the measured 7–11× decode-memory
win comes at a rank-controlled quality cost.  This benchmark quantifies the
dial on the reduced deepseek model: teacher-forced decode logit-KL vs the
dense-cache reference across ranks, at fixed dense-tail length.

(The same axes as paper Fig. 10, applied to the KV stream — the paper's
outlier observation suggests a K/V outlier-channel side-track as future
work; the base-rank dial is measured here.)
"""
from __future__ import annotations

from typing import List

import jax
import jax.numpy as jnp

from repro.configs import all_archs
from repro.models import decomposed_kv as DK
from repro.models import model_fns
from repro.models import transformer as T
from .common import Row


def run(quick: bool = False) -> List[Row]:
    cfg = all_archs()["deepseek-7b"].reduced().replace(num_layers=4)
    fns = model_fns(cfg)
    params = fns.init(jax.random.PRNGKey(0), cfg)
    seq = 48 if quick else 96
    prefix = seq - 8
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, seq), 0, cfg.vocab)

    # dense reference decode stream
    _, cache_d = T.prefill(params, cfg, toks[:, :prefix], seq + 8)
    ref = []
    cd = cache_d
    for t in range(prefix, seq):
        lg, cd = T.decode_step(params, cfg, toks[:, t], cd,
                               jnp.full((2,), t, jnp.int32))
        ref.append(jax.nn.log_softmax(lg.astype(jnp.float32), -1))

    rows: List[Row] = []
    kvw_full = cfg.num_kv_heads * cfg.resolved_head_dim
    full_rank = min(prefix, kvw_full)          # exact-recovery bound
    ranks = (4, 16) if quick else (4, 16, 32, full_rank)
    for r in ranks:
        _, ck = DK.prefill_dkv(params, cfg, toks[:, :prefix], rank=r,
                               tail=8, exact=(r == full_rank))
        kls = []
        for i, t in enumerate(range(prefix, seq)):
            lg, ck = DK.decode_step_dkv(params, cfg, toks[:, t], ck,
                                        jnp.full((2,), t, jnp.int32),
                                        frozen_len=prefix)
            lp = jax.nn.log_softmax(lg.astype(jnp.float32), -1)
            kls.append(float(jnp.mean(jnp.sum(jnp.exp(ref[i])
                                              * (ref[i] - lp), -1))))
        kvw = cfg.num_kv_heads * cfg.resolved_head_dim
        bytes_ratio = (prefix * kvw) / (prefix * r + r * kvw)
        rows.append((f"dkv_quality/rank{r}", 0.0,
                     f"decode_logit_kl={sum(kls) / len(kls):.4f};"
                     f"kv_bytes_reduction={bytes_ratio:.1f}x"))
    return rows


if __name__ == "__main__":
    from .common import emit
    emit(run())
