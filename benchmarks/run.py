"""Benchmark aggregator: one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV.  ``--quick`` shrinks shapes.
"""
from __future__ import annotations

import argparse
import sys
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--only", default=None,
                    help="comma-separated module suffixes to run")
    args = ap.parse_args()

    from . import (dkv_quality, engine_throughput, fig2_convergence,
                   fig3_breakdown, fig10_outliers, fig11_layer_runtime,
                   fig12_expansion, serving_admission, table2_table3_configs)
    mods = {
        "fig2": fig2_convergence, "fig3": fig3_breakdown,
        "fig10": fig10_outliers, "fig11": fig11_layer_runtime,
        "fig12": fig12_expansion, "table2_table3": table2_table3_configs,
        "dkv_quality": dkv_quality, "engine": engine_throughput,
        "serving": serving_admission,
    }
    if args.only:
        keep = args.only.split(",")
        mods = {k: v for k, v in mods.items() if k in keep}

    print("name,us_per_call,derived")
    ok = True
    for name, mod in mods.items():
        t0 = time.time()
        try:
            for row in mod.run(quick=args.quick):
                print(f"{row[0]},{row[1]:.3f},{row[2]}", flush=True)
            print(f"_meta/{name}_wall_s,{(time.time() - t0) * 1e6:.0f},ok",
                  flush=True)
        except Exception as e:                       # keep the suite going
            ok = False
            import traceback
            traceback.print_exc()
            print(f"_meta/{name},0,FAILED:{e}", flush=True)
    if not ok:
        sys.exit(1)


if __name__ == "__main__":
    main()
