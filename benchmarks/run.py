"""Benchmark aggregator: one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV.  ``--quick`` shrinks shapes.

``--tune`` is the autotuner entrypoint instead: measure-tune every
registered kernel space over representative shapes, persist the winners,
and emit the tuning cache as a JSON artifact (``--tune-out``) so CI can
carry it across runs and a deployment can ship it with the binary.
"""
from __future__ import annotations

import argparse
import os
import sys
import time


# Representative (kernel → shapes) for the tune entrypoint; --quick keeps
# the same kernels but shrinks every shape.
TUNE_SHAPES = {
    "lanczos_reorth": [(4, 256, 512), (8, 64, 1024)],
    "matvec_expand": [(1024, 2048)],
    "lowrank_matmul": [(16, 1024, 1024)],
    "dkv_attention": [(8, 1024, 32)],
    "decode_block": [(8, 128, 512)],       # (slots, horizon, kv width)
}
TUNE_SHAPES_QUICK = {
    "lanczos_reorth": [(2, 48, 96)],
    "matvec_expand": [(128, 256)],
    "lowrank_matmul": [(8, 128, 128)],
    "dkv_attention": [(4, 96, 16)],
    "decode_block": [(4, 16, 64)],
}


def run_tune(quick: bool, out_path: str) -> None:
    """Measure-tune every registered kernel and write the cache artifact."""
    from repro import tune

    from .common import write_json

    shapes = TUNE_SHAPES_QUICK if quick else TUNE_SHAPES
    cache = tune.default_cache()
    print("name,us_per_call,derived")
    for kernel in tune.available_spaces():
        fix = {"backend": "pallas_interpret"} \
            if kernel == "lanczos_reorth" else None
        for shape in shapes.get(kernel, ()):
            res = tune.tune(kernel, shape, "float32", fix=fix,
                            measure_candidates=True,
                            prune=tune.DEFAULT_PRUNE,
                            reps=3 if quick else 5, cache=cache)
            best = ",".join(f"{k}={v}" for k, v in sorted(res.best.items()))
            print(f"tune/{kernel}/{'x'.join(map(str, res.shape))},"
                  f"{(res.measured_s or 0.0) * 1e6:.3f},"
                  f"{res.source}:{best}", flush=True)
    # measure the backend choice itself and persist it as the machine's
    # backend="auto" answer (the engine_backend cache override)
    bres = tune.tune_backend(shape=(2, 48, 96) if quick else (4, 256, 512),
                             reps=2 if quick else 5, cache=cache)
    print(f"tune/engine_backend,{(bres.measured_s or 0.0) * 1e6:.3f},"
          f"measured:backend={bres.best['backend']}", flush=True)
    cache.save()
    write_json(out_path, {"path": cache.path, "entries": cache.as_dict()},
               indent=1, sort_keys=True)
    print(f"_meta/tune_cache,{len(cache)},{out_path}", flush=True)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--only", default=None,
                    help="comma-separated module suffixes to run")
    ap.add_argument("--tune", action="store_true",
                    help="run the autotuner entrypoint instead of the "
                         "figure benchmarks")
    ap.add_argument("--tune-out",
                    default=os.path.join(os.path.dirname(__file__), "out",
                                         "tune_cache.json"),
                    help="where --tune writes the cache artifact")
    args = ap.parse_args()

    if args.tune:
        run_tune(args.quick, args.tune_out)
        return

    from . import (dkv_quality, engine_throughput, fig2_convergence,
                   fig3_breakdown, fig10_outliers, fig11_layer_runtime,
                   fig12_expansion, serving_admission, table2_table3_configs)
    mods = {
        "fig2": fig2_convergence, "fig3": fig3_breakdown,
        "fig10": fig10_outliers, "fig11": fig11_layer_runtime,
        "fig12": fig12_expansion, "table2_table3": table2_table3_configs,
        "dkv_quality": dkv_quality, "engine": engine_throughput,
        "serving": serving_admission,
    }
    if args.only:
        keep = args.only.split(",")
        mods = {k: v for k, v in mods.items() if k in keep}

    print("name,us_per_call,derived")
    ok = True
    for name, mod in mods.items():
        t0 = time.perf_counter()
        try:
            for row in mod.run(quick=args.quick):
                print(f"{row[0]},{row[1]:.3f},{row[2]}", flush=True)
            print(f"_meta/{name}_wall_s,"
                  f"{(time.perf_counter() - t0) * 1e6:.0f},ok", flush=True)
        except Exception as e:                       # keep the suite going
            ok = False
            import traceback
            traceback.print_exc()
            print(f"_meta/{name},0,FAILED:{e}", flush=True)
    if not ok:
        sys.exit(1)


if __name__ == "__main__":
    main()
