"""Paper Fig. 10: outlier-extraction effect on model quality vs rank.

Container-feasible quality metric (DESIGN.md §7): logit KL divergence of the
decomposed model vs baseline on a reduced Llama2 (the paper uses arc_easy
accuracy / wikitext-2 perplexity on the full 7B — weights unavailable here).
Axes match the paper: rank ∈ {1, 10, 20}, outlier % ∈ {0, 1, 3, 5, 10}, on
the 4-layer decomposition config.
"""
from __future__ import annotations

from typing import List

import jax

from repro.configs import all_archs
from repro.configs.base import ShapeSpec
from repro.core.policy import DecompositionPolicy
from repro.models import decomposed as D
from repro.models import make_fake_batch, model_fns
from .common import Row


def _inject_channel_outliers(params, scale=12.0, n_channels=6):
    """Random-init models lack the persistent outlier channels of trained
    LLMs (paper Fig. 7); scaling a few embedding columns reproduces that
    structure through the residual stream (documented adaptation)."""
    import jax.numpy as jnp
    w = params["embed"]["w"]
    cols = jnp.arange(n_channels) * (w.shape[1] // n_channels)
    params["embed"]["w"] = w.at[:, cols].mul(scale)
    return params


def run(quick: bool = False) -> List[Row]:
    cfg = all_archs()["llama2-7b"].reduced().replace(num_layers=4)
    fns = model_fns(cfg)
    params = _inject_channel_outliers(
        fns.init(jax.random.PRNGKey(0), cfg))
    batch = make_fake_batch(cfg, ShapeSpec("bench", 64, 2, "train"))
    tokens = batch["tokens"]

    ranks = (1, 10) if quick else (1, 10, 20)
    fracs = (0.0, 0.03) if quick else (0.0, 0.01, 0.03, 0.05, 0.10)
    layers = [0, 2]                      # non-adjacent (paper's guidance)

    rows: List[Row] = []
    for r in ranks:
        kls = {}
        for frac in fracs:
            pol = DecompositionPolicy.from_layer_list(
                cfg.num_layers, layers, rank=min(r, 32),
                outlier_frac=frac, iters=min(r + 8, 48))
            kl = float(D.logit_kl(params, cfg, tokens,
                                  D.DecomposedRuntime(policy=pol)))
            kls[frac] = kl
            rows.append((f"fig10/rank{r}/outlier{frac:.0%}", 0.0,
                         f"logit_kl={kl:.4f}"))
        rows.append((f"fig10/rank{r}/outlier_gain", 0.0,
                     f"kl_0pct/kl_{max(fracs):.0%}="
                     f"{kls[0.0] / max(kls[max(fracs)], 1e-9):.2f}x"))
    return rows


if __name__ == "__main__":
    from .common import emit
    emit(run())
