"""Mesh-parallel serving A/B: 1 device vs 8 forced host devices.

The tentpole claim of mesh-parallel decomposed-KV serving: the SAME
continuous-batching workload (staggered arrivals, per-slot splice
admission, tail folds) runs on an 8-way DP host mesh with byte-identical
greedy tokens, and the A/B artifact records both arms' throughput so the
sharded path's overhead/benefit is tracked per commit.  On forced host
devices all 8 "devices" share one CPU, so tokens/sec parity — not
speedup — is the honest expectation; the artifact carries the raw numbers
and the token-conformance bit either way.

Each arm is a SUBPROCESS because jax locks the device count at first init
(the same pattern as tests/test_moe_shard_map.py): the parent sets
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` for the mesh arm
only, then merges the per-arm JSONs.

CLI (writes the CI artifact):

  PYTHONPATH=src python -m benchmarks.serving_sharded --quick \
      --json benchmarks/out/serving_sharded.json
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
import tempfile
import time
from typing import Dict, List

from .common import Row


def run_arm(mesh_spec: str, slots: int, requests: int, prompt_len: int,
            max_new: int, stagger: int, json_path: str) -> None:
    """One serving arm in THIS process (invoked as a subprocess)."""
    import jax
    import numpy as np
    from repro.configs import all_archs
    from repro.engine import DecomposeEngine, EngineConfig
    from repro.launch.mesh import parse_mesh
    from repro.models import model_fns
    from repro.serving import Engine, Request

    mesh = parse_mesh(mesh_spec)
    cfg = all_archs()["deepseek-7b"].reduced()
    params = model_fns(cfg).init(jax.random.PRNGKey(0), cfg)

    def serve():
        # fresh Request objects per pass (they carry mutable progress)
        rng = np.random.RandomState(0)
        reqs = [Request(uid=i,
                        prompt=rng.randint(0, cfg.vocab, prompt_len,
                                           dtype=np.int32),
                        max_new_tokens=max_new + (i % 3) * max_new // 2)
                for i in range(requests)]
        de = DecomposeEngine(EngineConfig(kv_rank=8, kv_tail=4, mesh=mesh))
        eng = Engine(cfg, params, slots=slots, max_len=192,
                     decompose_kv_rank=8, dkv_tail=4, decompose_engine=de)
        done: List = []
        step = 0
        while len(done) < requests and step < 5000:
            if step % stagger == 0 and step // stagger < requests:
                eng.submit(reqs[step // stagger])
            done.extend(eng.step())
            step += 1
        assert len(done) == requests, f"only {len(done)}/{requests} finished"
        return done, eng

    serve()                                  # warmup populates jit caches
    t0 = time.perf_counter()
    done, eng = serve()
    wall = time.perf_counter() - t0
    s = eng.stats
    report = {
        "mesh": mesh_spec, "devices": len(jax.devices()),
        "slots": slots, "requests": requests,
        "wall_s": wall, "tokens_out": s.tokens_out,
        "tokens_per_s": s.tokens_out / max(wall, 1e-9),
        "prefills": s.prefills, "prefill_batches": s.prefill_batches,
        "tail_folds": s.tail_folds,
        "mean_ttft_s": s.mean_ttft_s, "mean_itl_s": s.mean_itl_s,
        "tokens": {str(r.uid): r.out_tokens for r in done},
    }
    if mesh is not None:
        ku = eng.cache["k_u"]
        report["ku_nshards"] = len(ku.addressable_shards)
        report["ku_spec"] = str(ku.sharding.spec)
    with open(json_path, "w") as f:
        json.dump(report, f)


def run(quick: bool = False, json_path: str = None) -> List[Row]:
    slots = 8
    requests = 6 if quick else 10
    prompt_len, max_new, stagger = 12, 12 if quick else 24, 6

    arms = {"1dev": ("none", None),
            "8dev": ("8x1", "--xla_force_host_platform_device_count=8")}
    results: Dict[str, dict] = {}
    with tempfile.TemporaryDirectory() as td:
        for name, (mesh_spec, xla_flags) in arms.items():
            out = os.path.join(td, f"{name}.json")
            env = dict(os.environ,
                       PYTHONPATH="src" + os.pathsep
                       + os.environ.get("PYTHONPATH", ""))
            env.pop("XLA_FLAGS", None)
            if xla_flags:
                env["XLA_FLAGS"] = xla_flags
            code = (f"from benchmarks.serving_sharded import run_arm; "
                    f"run_arm({mesh_spec!r}, {slots}, {requests}, "
                    f"{prompt_len}, {max_new}, {stagger}, {out!r})")
            subprocess.run([sys.executable, "-c", code], check=True,
                           env=env, timeout=1800,
                           cwd=os.path.dirname(os.path.dirname(
                               os.path.abspath(__file__))))
            with open(out) as f:
                results[name] = json.load(f)

    toks_1, toks_8 = (results[a].pop("tokens") for a in ("1dev", "8dev"))
    tokens_match = toks_1 == toks_8
    if not tokens_match:                 # keep the evidence in the artifact
        results["1dev"]["tokens"], results["8dev"]["tokens"] = toks_1, toks_8
    report = {
        "arch": "deepseek-7b(reduced)", "slots": slots,
        "requests": requests, "kv_rank": 8,
        "arms": results,
        "tokens_byte_identical": tokens_match,
        "tokens_per_s_ratio_8dev_over_1dev":
            results["8dev"]["tokens_per_s"]
            / max(results["1dev"]["tokens_per_s"], 1e-9),
    }
    # artifact FIRST (it must carry the conformance bit — and the per-arm
    # stats needed to diagnose a divergence — even when the gate fails)
    if json_path:
        os.makedirs(os.path.dirname(json_path) or ".", exist_ok=True)
        with open(json_path, "w") as f:
            json.dump(report, f, indent=2)
    assert tokens_match, "sharded serving diverged from 1-device tokens"
    assert results["8dev"].get("ku_nshards") == 8, \
        "8dev arm did not actually shard the cache"
    rows: List[Row] = []
    for name, r in results.items():
        rows.append((f"serving_sharded/{name}/r{requests}xs{slots}",
                     r["wall_s"] * 1e6,
                     f"tok_per_s={r['tokens_per_s']:.1f};"
                     f"devices={r['devices']};folds={r['tail_folds']}"))
    rows.append(("serving_sharded/conformance", 0.0,
                 f"tokens_byte_identical={tokens_match};"
                 f"ratio={report['tokens_per_s_ratio_8dev_over_1dev']:.2f}x"))
    return rows


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--json", default=None, help="write the report here")
    args = ap.parse_args()
    for r in run(quick=args.quick, json_path=args.json):
        print(f"{r[0]},{r[1]:.3f},{r[2]}")
