"""Mesh-parallel serving A/B: 1 device vs 8 forced host devices,
single-step vs fused decode blocks.

The tentpole claim of mesh-parallel decomposed-KV serving: the SAME
continuous-batching workload (staggered arrivals, per-slot splice
admission, tail folds) runs on an 8-way DP host mesh with byte-identical
greedy tokens, and the A/B artifact records both arms' throughput so the
sharded path's overhead/benefit is tracked per commit.

The fused decode loop (``decode_block > 1``) is what makes the 8-device
arm competitive: single-step decode pays a host→device dispatch + host
sampling round-trip per token, which the mesh multiplies (the pre-fusion
artifact showed 8dev at ~0.1× the 1dev tok/s).  Each arm therefore
measures FOUR modes — {slot, paged} × {single, fused} — on identical
token streams, and the merged artifact carries fused-vs-single ratios per
engine plus the ROADMAP gate: **8dev fused tok/s ≥ 1dev fused tok/s**.

The ROADMAP gate is enforced only when the host has >= 8 usable cores:
forced host "devices" are threads over the same CPUs, so on a 1-core
container the 8-device arm pays 8x per-op dispatch with zero parallel
compute and can never reach parity — no amount of fusion changes the
physics.  What IS asserted unconditionally is the claim fusion actually
makes: the fused loop must IMPROVE the 8-device arm's tok/s over
single-step (it removes the per-token host round-trip the mesh
multiplies).  Both ratios land in the JSON artifact either way, with
``host_cores`` recording which regime the run measured.

Each arm is a SUBPROCESS because jax locks the device count at first init
(the same pattern as tests/test_moe_shard_map.py): the parent sets
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` for the mesh arm
only, then merges the per-arm JSONs.

CLI (writes the CI artifact):

  PYTHONPATH=src python -m benchmarks.serving_sharded --quick \
      --json benchmarks/out/serving_sharded.json
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
import tempfile
import time
from typing import Dict, List

from .common import Row, write_json

KV_RANK, KV_TAIL = 8, 8
FUSED_BLOCK = 8                          # capped by KV_TAIL anyway


def run_arm(mesh_spec: str, slots: int, requests: int, prompt_len: int,
            max_new: int, stagger: int, json_path: str) -> None:
    """One serving arm in THIS process (invoked as a subprocess):
    measures all four {engine} × {decode mode} combinations."""
    import jax
    import numpy as np
    from repro.configs import all_archs
    from repro.engine import DecomposeEngine, EngineConfig
    from repro.launch.mesh import parse_mesh
    from repro.models import model_fns
    from repro.obs import engine_snapshot
    from repro.serving import Engine, Request

    mesh = parse_mesh(mesh_spec)
    cfg = all_archs()["deepseek-7b"].reduced()
    params = model_fns(cfg).init(jax.random.PRNGKey(0), cfg)

    def serve(paged: bool, block: int):
        # fresh Request objects per pass (they carry mutable progress)
        rng = np.random.RandomState(0)
        reqs = [Request(uid=i,
                        prompt=rng.randint(0, cfg.vocab, prompt_len,
                                           dtype=np.int32),
                        max_new_tokens=max_new + (i % 3) * max_new // 2)
                for i in range(requests)]
        de = DecomposeEngine(EngineConfig(kv_rank=KV_RANK, kv_tail=KV_TAIL,
                                          decode_block=block, mesh=mesh))
        eng = Engine(cfg, params, slots=slots, max_len=192,
                     decompose_kv_rank=KV_RANK, dkv_tail=KV_TAIL,
                     decompose_engine=de, paged=paged)
        done: List = []
        nsub = 0
        for _ in range(5000):
            # arrivals are scheduled in ROUND space (request k lands at
            # decode round k·stagger) so every block size and both arms
            # see the identical admission schedule — and the next block
            # is cut at the next arrival, exactly as the fold/budget
            # horizons cut it, keeping tokens byte-identical
            rounds = eng.stats.decode_steps
            while nsub < requests and rounds >= nsub * stagger:
                eng.submit(reqs[nsub])
                nsub += 1
            eng.decode_block = block if nsub >= requests else \
                max(1, min(block, nsub * stagger - rounds))
            done.extend(eng.step())
            if len(done) >= requests:
                break
        assert len(done) == requests, f"only {len(done)}/{requests} finished"
        return done, eng

    report = {"mesh": mesh_spec, "devices": len(jax.devices()),
              "slots": slots, "requests": requests, "modes": {}}
    for name, (paged, block) in {
            "slot_single": (False, 1), "slot_fused": (False, FUSED_BLOCK),
            "paged_single": (True, 1), "paged_fused": (True, FUSED_BLOCK),
    }.items():
        serve(paged, block)              # warmup populates jit caches
        t0 = time.perf_counter()
        done, eng = serve(paged, block)
        wall = time.perf_counter() - t0
        # uniform repro.obs/v1 snapshot + arm-specific extras ("paged" is
        # the snapshot's pool block, so the mode flag is "is_paged")
        report["modes"][name] = engine_snapshot(
            eng, wall_s=wall, is_paged=paged, decode_block=block,
            tokens={str(r.uid): r.out_tokens for r in done})
        if mesh is not None and not paged:
            ku = eng.cache["k_u"]
            report["ku_nshards"] = len(ku.addressable_shards)
            report["ku_spec"] = str(ku.sharding.spec)
    write_json(json_path, report)


def run(quick: bool = False, json_path: str = None) -> List[Row]:
    slots = 8
    requests = 6 if quick else 10
    prompt_len, max_new, stagger = 12, 12 if quick else 24, 6

    arms = {"1dev": ("none", None),
            "8dev": ("8x1", "--xla_force_host_platform_device_count=8")}
    results: Dict[str, dict] = {}
    with tempfile.TemporaryDirectory() as td:
        for name, (mesh_spec, xla_flags) in arms.items():
            out = os.path.join(td, f"{name}.json")
            env = dict(os.environ,
                       PYTHONPATH="src" + os.pathsep
                       + os.environ.get("PYTHONPATH", ""))
            env.pop("XLA_FLAGS", None)
            if xla_flags:
                env["XLA_FLAGS"] = xla_flags
            code = (f"from benchmarks.serving_sharded import run_arm; "
                    f"run_arm({mesh_spec!r}, {slots}, {requests}, "
                    f"{prompt_len}, {max_new}, {stagger}, {out!r})")
            subprocess.run([sys.executable, "-c", code], check=True,
                           env=env, timeout=3600,
                           cwd=os.path.dirname(os.path.dirname(
                               os.path.abspath(__file__))))
            with open(out) as f:
                results[name] = json.load(f)

    # every mode of every arm must emit the SAME token streams
    token_sets = {f"{arm}/{mode}": m.pop("tokens")
                  for arm, r in results.items()
                  for mode, m in r["modes"].items()}
    ref_key = "1dev/slot_single"
    ref = token_sets[ref_key]
    mismatched = sorted(k for k, t in token_sets.items() if t != ref)
    if mismatched:                       # keep the evidence in the artifact
        for k in mismatched + [ref_key]:
            arm, mode = k.split("/")
            results[arm]["modes"][mode]["tokens"] = token_sets[k]

    def tps(arm, mode):
        return results[arm]["modes"][mode]["tokens_per_s"]

    try:
        host_cores = len(os.sched_getaffinity(0))
    except AttributeError:               # non-Linux fallback
        host_cores = os.cpu_count() or 1

    report = {
        "arch": "deepseek-7b(reduced)", "slots": slots,
        "requests": requests, "kv_rank": KV_RANK,
        "decode_block": FUSED_BLOCK, "host_cores": host_cores,
        "arms": results,
        "tokens_byte_identical": not mismatched,
        "fused_over_single": {
            f"{arm}/{eng}": tps(arm, f"{eng}_fused")
            / max(tps(arm, f"{eng}_single"), 1e-9)
            for arm in results for eng in ("slot", "paged")},
        "tokens_per_s_ratio_8dev_over_1dev_single":
            tps("8dev", "slot_single") / max(tps("1dev", "slot_single"),
                                             1e-9),
        "tokens_per_s_ratio_8dev_over_1dev_fused":
            tps("8dev", "slot_fused") / max(tps("1dev", "slot_fused"), 1e-9),
    }
    # artifact FIRST (it must carry the conformance bit — and the per-arm
    # stats needed to diagnose a divergence — even when the gate fails)
    if json_path:
        write_json(json_path, report, indent=2)
    assert not mismatched, \
        f"serving modes diverged from {ref_key}: {mismatched}"
    assert results["8dev"].get("ku_nshards") == 8, \
        "8dev arm did not actually shard the cache"
    # fusion's own claim, asserted everywhere: killing the per-token host
    # round-trip must speed up the mesh arm (it multiplies that overhead)
    for eng_kind in ("slot", "paged"):
        r = report["fused_over_single"][f"8dev/{eng_kind}"]
        assert r >= 1.0, \
            f"fused loop did not improve 8dev {eng_kind} arm: {r:.2f}x"
    # THE ROADMAP bar: with fusion on, the 8-device mesh must at least
    # match 1-device throughput.  Only meaningful where the 8 forced
    # host devices can actually run concurrently — with < 8 usable
    # cores they time-slice one CPU and parity is physically
    # unreachable, so the gate records itself as skipped instead.
    ratio = report["tokens_per_s_ratio_8dev_over_1dev_fused"]
    if host_cores >= 8:
        assert ratio >= 1.0, f"8dev fused below 1dev fused: {ratio:.2f}x"
        gate = f"enforced({ratio:.2f}x)"
    else:
        gate = f"skipped:{host_cores}_cores({ratio:.2f}x)"
    report["gate_8dev_ge_1dev_fused"] = gate
    if json_path:                        # rewrite with the gate outcome
        write_json(json_path, report, indent=2)
    rows: List[Row] = []
    for arm, r in results.items():
        for mode, m in r["modes"].items():
            rows.append((f"serving_sharded/{arm}/{mode}", m["wall_s"] * 1e6,
                         f"tok_per_s={m['tokens_per_s']:.1f};"
                         f"blocks={m['blocks']};folds={m['tail_folds']}"))
    rows.append(("serving_sharded/conformance", 0.0,
                 f"tokens_byte_identical={not mismatched};"
                 f"gate_8dev_ge_1dev_fused={gate}"))
    return rows


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--json", default=None, help="write the report here")
    args = ap.parse_args()
    for r in run(quick=args.quick, json_path=args.json):
        print(f"{r[0]},{r[1]:.3f},{r[2]}")
