"""Paper Fig. 4 / Fig. 11a: single-layer runtime — original vs decomposed
(no acceleration) vs decomposed (D-com co-accelerator).

Three sections:
1. MEASURED (CPU, scaled geometry) — preserved-GEMM speedup is real on any
   backend; note the naive-decomposition slowdown is a GPU-regime effect
   (tensor-core GEMMs are fast, unfused vector chains are launch-bound) so
   the CPU B/A ratio inverts — the modeled sections cover that regime.
2. MODELED, paper-faithful — A100-class GEMM engine (312 TFLOP/s fp16,
   2 TB/s HBM, 8 µs kernel overhead, 15% effective bw on unfused vector
   chains) + D-com decomposer (fig12 model, f = 8).  Reproduces the paper's
   2.3× naive slowdown, ~3.8× D-com speedup vs A, ~8.7× vs B.
3. MODELED, beyond-paper TPU-native — v5e with the decomposition held
   VMEM-RESIDENT across Lanczos iterations (the TPU analogue of D-com's
   distributed SRAM banks: one HBM load, then iterate at VMEM bandwidth).
   This is the §Perf "beyond-paper" datapoint for serving cells.

Geometry: Llama2-7b-like layer (4 × [4096,4096] GEMM chain), batch 64,
S = 4096, rank 10 (paper Fig. 4 setting).
"""
from __future__ import annotations

from typing import Dict, List

import jax
import jax.numpy as jnp

from repro.core import decompose, lowrank_matmul
from .common import HBM_BW, PEAK_FLOPS, Row, wall
from .fig12_expansion import batch_decomposition_latency

S = H = 4096
BATCH = 64
N_MM = 4
RANK = 10

# paper-faithful GPU-regime constants
A100_FLOPS = 312e12
A100_BW = 2.0e12
LAUNCH = 8e-6                 # kernel launch + sync
VEC_EFF = 0.15                # effective bw of unfused short-vector chains
OPS_PER_ITER = 12             # matvec + 2×CGS2 (proj, correction) ×2 + norms

# beyond-paper v5e constants
VMEM_BW = 20e12               # sustained VMEM bandwidth


def measured(quick: bool) -> List[Row]:
    s, h = (512, 512) if quick else (1024, 2048)
    b = 2
    x = jax.random.normal(jax.random.PRNGKey(0), (b, s, h), jnp.float32)
    w = [jax.random.normal(jax.random.PRNGKey(i), (h, h), jnp.float32) * 0.02
         for i in range(N_MM)]

    @jax.jit
    def dense_layer(x):
        y = x
        for wi in w:
            y = y @ wi
        return y

    @jax.jit
    def decomposed_layer(x):
        lr = decompose(x, RANK, iters=RANK + 4)
        out = lr
        for wi in w:
            out = lowrank_matmul(out, wi)
        return out.vt

    @jax.jit
    def preserved_only(u, s_, vt):
        from repro.core.lowrank import LowRank
        out = LowRank(u, s_, vt)
        for wi in w:
            out = lowrank_matmul(out, wi)
        return out.vt

    t_a = wall(dense_layer, x)
    t_b = wall(decomposed_layer, x)
    lr0 = decompose(x, RANK, iters=RANK + 4)
    t_c = wall(preserved_only, lr0.u, lr0.core, lr0.vt)
    return [
        ("fig11/measured/A_dense_layer", t_a * 1e6, f"S={s},H={h},B={b}"),
        ("fig11/measured/B_decomp_plus_preserved", t_b * 1e6,
         f"ratio_vs_A={t_b / t_a:.2f}x (CPU regime; see modeled)"),
        ("fig11/measured/C_preserved_gemms_only", t_c * 1e6,
         f"speedup_vs_A={t_a / t_c:.2f}x (Eq.6 chain, decomposer offloaded)"),
    ]


def modeled_paper() -> List[Row]:
    """Paper-faithful A100 + D-com model."""
    # A: dense layer GEMMs, compute-bound on tensor cores
    fl_a = BATCH * N_MM * 2 * S * H * H
    t_a = max(fl_a / A100_FLOPS, BATCH * N_MM * (2 * S * H + H * H) * 2
              / A100_BW)
    # naive on-device decomposition: unfused vector chain, launch-bound
    a_pass = S * H * 2 / (VEC_EFF * A100_BW)
    t_iter = 2 * (LAUNCH + a_pass) + (OPS_PER_ITER - 2) * LAUNCH
    t_dec_naive = t_iter * RANK * BATCH
    # preserved GEMMs (Eq. 6): skinny, memory-bound on W
    by_c = N_MM * (H * H * 2 + BATCH * 2 * RANK * H * 2)
    fl_c = BATCH * N_MM * 2 * RANK * H * H
    t_gemm = max(fl_c / A100_FLOPS, by_c / A100_BW)
    t_b = t_dec_naive + t_gemm
    # D-com decomposer (fig12 model at f*=8), overlapped with the GEMM
    t_dcom = batch_decomposition_latency(8)
    t_c = max(t_gemm, t_dcom)
    return [
        ("fig11/modeled_paper/A_dense", t_a * 1e6, "A100-class GEMM engine"),
        ("fig11/modeled_paper/B_naive_decomposed", t_b * 1e6,
         f"slowdown_vs_A={t_b / t_a:.2f}x (paper: ~2.3x)"),
        ("fig11/modeled_paper/C_dcom", t_c * 1e6,
         f"speedup_vs_A={t_a / t_c:.2f}x (paper: 3.8x); "
         f"speedup_vs_B={t_b / t_c:.2f}x (paper: 8.74x)"),
        ("fig11/modeled_paper/decomp_accel", 0.0,
         f"naive/dcom={t_dec_naive / t_dcom:.2f}x (paper: ~8x)"),
    ]


def modeled_v5e() -> List[Row]:
    """Beyond-paper: VMEM-resident decomposer on v5e (one HBM load, then
    all 2K reorth passes at VMEM bandwidth) + preserved GEMMs."""
    fl_a = BATCH * N_MM * 2 * S * H * H
    t_a = max(fl_a / PEAK_FLOPS,
              BATCH * N_MM * (2 * S * H + H * H) * 2 / HBM_BW)
    a_bytes = S * H * 2
    t_load = a_bytes / HBM_BW
    t_iter = max(a_bytes / VMEM_BW, 2 * S * H / PEAK_FLOPS)
    t_dec = (t_load + 2 * RANK * t_iter) * BATCH
    by_c = N_MM * (H * H * 2 + BATCH * 2 * RANK * H * 2)
    fl_c = BATCH * N_MM * 2 * RANK * H * H
    t_gemm = max(fl_c / PEAK_FLOPS, by_c / HBM_BW)
    t_c = max(t_gemm, t_dec)
    return [
        ("fig11/modeled_v5e/A_dense", t_a * 1e6, ""),
        ("fig11/modeled_v5e/decomposer_vmem_resident", t_dec * 1e6,
         f"vs naive HBM-streaming "
         f"{(2 * RANK * BATCH * a_bytes / HBM_BW) / t_dec:.1f}x"),
        ("fig11/modeled_v5e/C_overlap", t_c * 1e6,
         f"speedup_vs_A={t_a / t_c:.2f}x (beyond-paper TPU-native)"),
    ]


def run(quick: bool = False) -> List[Row]:
    return measured(quick) + modeled_paper() + modeled_v5e()


if __name__ == "__main__":
    from .common import emit
    emit(run())
